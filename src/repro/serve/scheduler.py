"""Continuous-batching scheduler: per-step admit / prefill / decode.

The batch-synchronous ``Engine.serve`` loop admits one equal-length
batch, prefills it once, and decodes until the *last* request finishes
— short requests ride along as dead rows and a new request waits for
the whole batch to drain.  ``ContinuousScheduler`` replaces that with
a slot machine over the ragged cache the PR-8 kernels understand:

  * the KV cache keeps a fixed ``max_batch`` rows at ``max_len``
    (fixed shapes -> one decode trace, bitwise-deterministic replay),
    with a *vector* ``index`` — each row's filled length.  The decode
    step bands attention per row (``kv_len`` as a scalar-prefetch
    array), so a row at position 12 pays for 12 positions of KV
    traffic while its neighbor sits at 1900;
  * each ``step()`` admits at most one waiting request into a free
    slot (whole-prompt prefill, or one chunk of a long prompt when
    ``prefill_chunk`` is set — chunked prefill interleaves with decode
    so running requests never stall behind a long prompt), then runs
    one vectorized decode step for every occupied slot;
  * requests finish (DONE / EVICTED / FAILED) individually: their slot
    frees immediately and the next waiting request takes it on the
    following step — no batch barrier;
  * with a ``PagedKVCache`` attached, each admitted prompt's KV is
    also scattered into refcounted pages and full-page prefixes are
    shared across requests (``lookup_prefix``): a reused prefix skips
    its share of prefill compute, and the pages double as the
    block-table rows ``ops.paged_attention`` turns into kernel index
    maps.

Determinism contract (what the ragged crash drill pins): admission
order is the enqueue order (rid order under ``Engine.drain``), slots
are assigned lowest-free-first, prefill uses the engine's own jitted
functions, and free slots' cache rows are reset to index 0 after every
step — so a cold journal replay that re-enqueues the same rids walks
the identical slot/batch evolution and regenerates bit-identical
greedy tokens.

Faults route through ``Engine._execute`` under the same
``serve.prefill`` / ``serve.decode_step`` injection sites as the
batch-synchronous loop, so every registered drill (degradation,
retry, SIGKILL) exercises this loop unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, lm
from repro.serve.paged_cache import PagedKVCache, pages_for


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling settings for the handle/stream API."""
    max_new_tokens: int = 16
    greedy: bool = True
    seed: int = 0
    deadline_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Continuous-batching knobs.

    ``max_batch``     decode slots (cache rows) — fixed, so the decode
                      trace never re-specializes as requests come/go.
    ``prefill_chunk`` 0 prefills whole prompts in one shot (and reuses
                      the engine's jitted prefill — bit-identical to
                      the batch-sync loop); >0 streams prompts longer
                      than the chunk through ``lm.prefill_chunk`` one
                      chunk per step, interleaved with decode.
    ``page_size`` / ``n_pages`` size the shared ``PagedKVCache``;
                      ``n_pages=0`` sizes it to hold ``max_batch`` full
                      ``max_len`` rows.  ``page_size=0`` disables
                      paging (slot cache only).
    ``prefix_reuse``  share full-page common prefixes across requests.
    """
    max_batch: int = 4
    prefill_chunk: int = 0
    page_size: int = 16
    n_pages: int = 0
    prefix_reuse: bool = True


class ContinuousScheduler:
    """Slot-based continuous batching over one ``Engine``.

    The scheduler borrows the engine's jitted prefill/decode functions,
    degradation policy, journal and counters; it owns the waiting
    queue, the slot table, the ragged cache and the page pool.
    """

    def __init__(self, engine, config: Optional[SchedulerConfig] = None):
        from repro.serve import engine as engine_mod   # circular-safe
        self._E = engine_mod
        self.eng = engine
        self.cc = config or SchedulerConfig()
        if self.cc.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{self.cc.max_batch}")
        self.waiting: deque = deque()
        self.slots: List[Optional[Any]] = [None] * self.cc.max_batch
        self.cache = None                      # ragged slot cache
        self.last_tok = np.zeros(self.cc.max_batch, np.int64)
        self.step_count = 0
        self.greedy = True
        self.seed = 0
        self.t_start: Dict[int, float] = {}
        self.req_pages: Dict[int, List[int]] = {}
        self.paged: Optional[PagedKVCache] = None
        self._pf: Optional[Tuple] = None       # chunked prefill in flight
        self._chunk_fns: Dict[int, Tuple] = {} # chunk len -> jitted pair
        cfg = engine.cfg
        if self.cc.page_size and getattr(cfg, "has_attention", True) \
                and getattr(cfg, "kv_cache_dtype", "auto") != "int8":
            n_pages = self.cc.n_pages or (
                self.cc.max_batch
                * pages_for(engine.max_len, self.cc.page_size))
            self.paged = PagedKVCache(cfg, n_pages, self.cc.page_size,
                                      dtype=cfg.act_dtype)

    # ------------------------------------------------------------------
    # Queue.
    # ------------------------------------------------------------------
    def enqueue(self, req) -> None:
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self._pf is not None
                    or any(r is not None for r in self.slots))

    def inflight(self) -> List[Any]:
        """Every request the scheduler currently owns (queued, mid-
        prefill, or decoding)."""
        out = [r for r in self.waiting]
        if self._pf is not None:
            out.append(self._pf[0])
        out.extend(r for r in self.slots if r is not None)
        return out

    # ------------------------------------------------------------------
    # The step: admit (one prefill unit) then decode (all slots).
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduler tick; returns True if any work was done."""
        did = self._admit()
        did = self._decode() or did
        return did

    def drain(self, greedy: bool = True, seed: int = 0) -> None:
        """Step until every owned request is terminal."""
        self.greedy, self.seed = bool(greedy), int(seed)
        while self.has_work:
            if not self.step():
                break                      # defensive: no progress
        self.greedy, self.seed = True, 0

    # -- admission ------------------------------------------------------
    def _admit(self) -> bool:
        if self._pf is not None:
            return self._advance_chunked()
        while self.waiting:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                return False
            req = self.waiting.popleft()
            if req.state != self._E.RequestState.QUEUED:
                continue                   # served elsewhere / stale
            self._ensure_cache()
            self.t_start.setdefault(req.rid, time.monotonic())
            plen = int(req.prompt.shape[0])
            self.eng._warm_autotune(1, plen)
            if self.cc.prefill_chunk and plen > self.cc.prefill_chunk:
                self._pf = (req, None, 0)
                return self._advance_chunked()
            return self._prefill_whole(req, free[0])
        return False

    def _ensure_cache(self) -> None:
        if self.cache is None:
            self.cache = lm.init_cache(
                self.eng.cfg, self.cc.max_batch, self.eng.max_len,
                dtype=self.eng.cfg.act_dtype)
            self.cache["index"] = jnp.zeros((self.cc.max_batch,),
                                            jnp.int32)

    def _prefill_whole(self, req, slot: int) -> bool:
        """Single-shot prefill through the engine's own jitted function
        (B=1), then install the row into ``slot``."""
        RequestState = self._E.RequestState
        prompt = np.asarray(req.prompt, np.int32)
        plen = len(prompt)
        reuse, covered = [], 0
        if self.paged is not None and self.cc.prefix_reuse:
            reuse, covered = self.paged.lookup_prefix(prompt)
        req.state = RequestState.PREFILLING
        dev = jnp.asarray(prompt[None])
        try:
            if covered:
                logits, rcache = self._prefill_from_pages(
                    prompt, reuse, covered)
            else:
                logits, rcache, path = self.eng._execute(
                    "serve.prefill", self.step_count,
                    lambda: self.eng._prefill(self.eng.params, dev),
                    lambda: self.eng._prefill_degraded(self.eng.params,
                                                       dev))
                if path == "degraded":
                    self.eng._counters["degraded_steps"] += 1
        except self._E.StepFailed as e:
            self._fail(req, e)
            if reuse:
                self.paged.release(reuse)
            return True
        self._store_pages(req, prompt, reuse, covered, rcache)
        self._install(req, slot, rcache, plen, logits[0])
        return True

    def _prefill_from_pages(self, prompt, reuse: List[int],
                            covered: int):
        """Seed a fresh cache row from reused prefix pages, then prefill
        only the uncovered tail via ``lm.prefill_chunk``."""
        kp, vp = self.paged.gather(reuse)     # (L, n_kv, covered.., Dh)
        rcache = lm.init_cache(self.eng.cfg, 1, self.eng.max_len,
                               dtype=self.eng.cfg.act_dtype)
        rcache["k"] = rcache["k"].at[:, 0, :, :covered].set(
            kp[:, :, :covered].astype(rcache["k"].dtype))
        rcache["v"] = rcache["v"].at[:, 0, :, :covered].set(
            vp[:, :, :covered].astype(rcache["v"].dtype))
        rcache["index"] = jnp.asarray(covered, jnp.int32)
        tail = jnp.asarray(np.asarray(prompt[covered:], np.int32)[None])
        primary, degraded = self._chunk_fn(int(tail.shape[1]))
        start = jnp.asarray(covered, jnp.int32)
        logits, rcache, path = self.eng._execute(
            "serve.prefill", self.step_count,
            lambda: primary(self.eng.params, rcache, tail, start),
            lambda: degraded(self.eng.params, rcache, tail, start))
        if path == "degraded":
            self.eng._counters["degraded_steps"] += 1
        return logits, rcache

    def _advance_chunked(self) -> bool:
        """Push one chunk of the in-flight long prompt; on the final
        chunk, install the finished row into a free slot."""
        RequestState = self._E.RequestState
        req, rcache, pos = self._pf
        prompt = np.asarray(req.prompt, np.int32)
        plen = len(prompt)
        end = min(pos + self.cc.prefill_chunk, plen)
        toks = jnp.asarray(prompt[None, pos:end])
        req.state = RequestState.PREFILLING
        try:
            if rcache is None:
                rcache = lm.init_cache(self.eng.cfg, 1, self.eng.max_len,
                                       dtype=self.eng.cfg.act_dtype)
            primary, degraded = self._chunk_fn(int(toks.shape[1]))
            start = jnp.asarray(pos, jnp.int32)
            logits, rcache, path = self.eng._execute(
                "serve.prefill", self.step_count,
                lambda: primary(self.eng.params, rcache, toks, start),
                lambda: degraded(self.eng.params, rcache, toks, start))
            if path == "degraded":
                self.eng._counters["degraded_steps"] += 1
        except self._E.StepFailed as e:
            self._pf = None
            self._fail(req, e)
            return True
        if end < plen:
            self._pf = (req, rcache, end)
            return True
        self._pf = None
        free = [i for i, r in enumerate(self.slots) if r is None]
        self._store_pages(req, prompt, [], 0, rcache)
        self._install(req, free[0], rcache, plen, logits[0])
        return True

    def _chunk_fn(self, chunk_len: int) -> Tuple:
        """Jitted ``prefill_chunk`` (+ degraded XLA twin) per chunk
        length; ``start`` is traced so one trace serves every offset."""
        fns = self._chunk_fns.get(chunk_len)
        if fns is not None:
            return fns
        cfg = self.eng.cfg

        def _chunk(params, cache, toks, start):
            return lm.prefill_chunk(params, cache, toks, cfg, start)

        def _chunk_xla(params, cache, toks, start):
            with layers.forced_backend("xla"):
                return lm.prefill_chunk(params, cache, toks, cfg, start)

        fns = (jax.jit(_chunk), jax.jit(_chunk_xla))
        self._chunk_fns[chunk_len] = fns
        return fns

    def _store_pages(self, req, prompt, reuse: List[int], covered: int,
                     rcache) -> None:
        """Scatter the prefilled row into the page pool (best effort:
        pool exhaustion falls back to slot-cache-only)."""
        if self.paged is None or "k" not in rcache:
            return
        plen = len(prompt)
        new = self.paged.alloc(
            pages_for(plen, self.cc.page_size) - len(reuse))
        if new is None:
            if reuse:
                self.paged.release(reuse)
            return
        pages = list(reuse) + new
        self.paged.store(prompt, pages, covered,
                         rcache["k"][:, 0], rcache["v"][:, 0])
        self.req_pages[req.rid] = pages

    def _install(self, req, slot: int, rcache, plen: int,
                 first_logits) -> None:
        """Copy the B=1 prefilled row into the slot cache and emit the
        prompt's first generated token."""
        for key, arr in self.cache.items():
            if key == "index":
                continue
            self.cache[key] = arr.at[:, slot].set(
                rcache[key][:, 0].astype(arr.dtype))
        self.cache["index"] = self.cache["index"].at[slot].set(plen)
        req.state = self._E.RequestState.DECODING
        self.slots[slot] = req
        self._emit(slot, first_logits)

    # -- decode ---------------------------------------------------------
    def _decode(self) -> bool:
        RequestState = self._E.RequestState
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return False
        now = time.monotonic()
        evicted = False
        for i in active:
            r = self.slots[i]
            dl = r.deadline_s
            if dl is not None and now - self.t_start[r.rid] > dl:
                r.state = RequestState.EVICTED
                r.error = (f"deadline {dl:.3f}s exceeded after "
                           f"{len(r.out_tokens)} tokens")
                self.eng._counters["evicted"] += 1
                self.eng.monitor.note("evicted", site="serve.decode_step",
                                      step=self.step_count, detail=r.error)
                self.eng._journal_terminal(r, self.step_count)
                self._free_slot(i)
                evicted = True
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return evicted
        self.step_count += 1
        toks = jnp.asarray(self.last_tok[:, None].astype(np.int32))
        cache = self.cache
        t0 = time.monotonic()
        try:
            logits, cache, path = self.eng._execute(
                "serve.decode_step", self.step_count,
                lambda: self.eng._decode(self.eng.params, cache, toks),
                lambda: self.eng._decode_degraded(self.eng.params, cache,
                                                  toks))
        except self._E.StepFailed as e:
            for i in active:
                self._fail(self.slots[i], e)
                self._free_slot(i)
            return True
        self.cache = cache
        if path == "degraded":
            self.eng._counters["degraded_steps"] += 1
            for i in active:
                self.slots[i].degraded_steps += 1
        self.eng.monitor.record(self.step_count, time.monotonic() - t0)
        logits_np = np.asarray(logits)
        for i in active:
            self._emit(i, logits_np[i])
        # park freed rows at index 0 so the cache state is a pure
        # function of the live requests (deterministic replay)
        occupied = np.asarray(
            [r is not None for r in self.slots], bool)
        self.cache["index"] = jnp.where(
            jnp.asarray(occupied), self.cache["index"], 0)
        return True

    def _emit(self, slot: int, logits_row) -> None:
        """Sample one token for ``slot``, journal it, finish on budget."""
        RequestState = self._E.RequestState
        req = self.slots[slot]
        sp = getattr(req, "sampling", None)
        greedy = self.greedy if sp is None else sp.greedy
        if greedy:
            t = int(np.argmax(np.asarray(logits_row)))
        else:
            seed = self.seed if sp is None else sp.seed
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), req.rid),
                len(req.out_tokens))
            t = int(jax.random.categorical(
                key, jnp.asarray(logits_row)))
        req.out_tokens.append(t)
        self.last_tok[slot] = t
        if self.eng.journal is not None:
            self.eng.journal.append("token", rid=req.rid,
                                    step=len(req.out_tokens), token=t)
        if len(req.out_tokens) >= req.max_new_tokens:
            req.state = RequestState.DONE
            self.eng._counters["completed"] += 1
            self.eng._journal_terminal(req, self.step_count)
            self._free_slot(slot)

    # -- bookkeeping ----------------------------------------------------
    def _fail(self, req, err: BaseException) -> None:
        req.state = self._E.RequestState.FAILED
        req.error = str(err)
        self.eng._counters["failed"] += 1
        self.eng._journal_terminal(req, self.step_count)
        pages = self.req_pages.pop(req.rid, None)
        if pages is not None:
            self.paged.release(pages)

    def _free_slot(self, slot: int) -> None:
        req = self.slots[slot]
        self.slots[slot] = None
        self.last_tok[slot] = 0
        self.t_start.pop(req.rid, None)
        pages = self.req_pages.pop(req.rid, None)
        if pages is not None:
            self.paged.release(pages)

    def report(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "steps": self.step_count,
            "waiting": len(self.waiting),
            "active": sum(r is not None for r in self.slots),
            "max_batch": self.cc.max_batch,
        }
        if self.paged is not None:
            out["pages"] = self.paged.report()
        return out
