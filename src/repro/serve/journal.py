"""Durable request journal: append-only JSONL write-ahead log.

Every state transition the serving engine makes is journaled *before*
it is acted on, so a process kill can never lose a request — the
restarted engine rebuilds the request table from the journal and
replays in-flight requests to their exact decode position
(serve/engine.py ``Engine.restore``).  Record kinds:

    submit    rid, prompt (token list), max_new_tokens, deadline_s —
              written at admission, fsync'd (a request the caller was
              told is admitted must survive a crash)
    serve     rids (batch order), seed, greedy, prompt_len — the batch
              composition a recovery must reproduce
    token     rid, step, token — one per emitted token (flushed, not
              fsync'd: greedy decode is deterministic, so a lost tail
              of token records is recomputed bit-exactly from params +
              prompt; the fsync is saved for the transitions that are
              *not* recomputable)
    snapshot  step — marks that ``Engine.snapshot`` committed a
              checkpoint covering everything before it
    preempt   rid, step, tokens_done — memory-pressure preemption
              (fsync'd): the request's pages were released and it was
              re-queued; its journaled tokens stay as replay
              expectations for the deterministic recompute
    done / failed / evicted
              rid, step, error — terminal transitions, fsync'd

Corruption contract (same as the PR-6 autotune store): each line is a
``{"rec": ..., "sum": <crc32>}`` envelope over the canonical JSON of
the record.  ``scan`` validates per record — a bit-flipped or
truncated line (e.g. the torn tail a mid-append kill leaves) is
skipped and counted, never fatal, and never poisons its neighbors.

The ``journal.append`` fault-injection site fires before any bytes are
written, so an armed ``kill`` drills the crash-before-durable window
and an armed ``raise`` drills the degraded-durability path: append
failures are counted (``stats()['append_errors']``), not raised —
losing the journal degrades crash *recovery*, it must not take down
crash-free *serving*.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, List, Optional

from repro.runtime import health

health.register_site("journal.append")


def journal_dir() -> Optional[str]:
    """The ``REPRO_JOURNAL_DIR`` env flag: default location engines
    journal to when not given an explicit directory."""
    return os.environ.get("REPRO_JOURNAL_DIR") or None


def _checksum(rec: dict) -> int:
    blob = json.dumps(rec, sort_keys=True, separators=(",", ":")).encode()
    return zlib.crc32(blob) & 0xFFFFFFFF


class RequestJournal:
    """Append-only JSONL journal with per-record CRC-32 envelopes."""

    def __init__(self, directory: str, name: str = "journal.jsonl"):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, name)
        self._f = None
        self._stats: Dict[str, int] = {
            "appends": 0,         # records durably handed to the OS
            "fsyncs": 0,          # appends that also forced the platters
            "append_errors": 0,   # I/O or injected faults (degraded)
            "records_loaded": 0,  # scan: envelope + CRC accepted
            "records_skipped": 0,  # scan: malformed / checksum-failed
            "torn_tail": 0,       # scan: unterminated final line dropped
        }

    # -- write --------------------------------------------------------------
    def _file(self):
        if self._f is None or self._f.closed:
            self._f = open(self.path, "a")
        return self._f

    def append(self, kind: str, fsync: bool = False, **fields) -> dict:
        """Journal one record; returns it.  Never raises: a failed
        append (disk full, injected fault) is counted in
        ``stats()['append_errors']`` and serving continues with
        degraded durability."""
        rec = {"kind": kind, **fields}
        line = json.dumps({"rec": rec, "sum": _checksum(rec)},
                          sort_keys=True, separators=(",", ":"))
        try:
            health.maybe_inject("journal.append")
            f = self._file()
            f.write(line + "\n")
            f.flush()
            if fsync:
                os.fsync(f.fileno())
                self._stats["fsyncs"] += 1
            self._stats["appends"] += 1
        except (OSError, ValueError, health.SimulatedFailure):
            self._stats["append_errors"] += 1
        return rec

    def close(self) -> None:
        if self._f is not None and not self._f.closed:
            self._f.close()
        self._f = None

    # -- read ---------------------------------------------------------------
    def scan(self) -> List[dict]:
        """Validated records, in append order.

        Containment mirrors ``core.autotune``: a missing file is an
        empty journal; an unterminated final line (mid-append kill) is
        a torn tail, dropped and counted; any other malformed or
        CRC-mismatched line is skipped and counted.  Never raises past
        here for corruption.
        """
        try:
            with open(self.path) as f:
                raw = f.read()
        except FileNotFoundError:
            return []
        records: List[dict] = []
        lines = raw.split("\n")
        if lines and lines[-1] == "":
            lines.pop()                      # clean terminator
        elif lines and lines[-1] != "":
            self._stats["torn_tail"] += 1    # kill mid-append
            lines.pop()
        for line in lines:
            rec = self._validate(line)
            if rec is None:
                self._stats["records_skipped"] += 1
            else:
                self._stats["records_loaded"] += 1
                records.append(rec)
        return records

    @staticmethod
    def _validate(line: str) -> Optional[dict]:
        try:
            env = json.loads(line)
        except ValueError:
            return None
        if not isinstance(env, dict):
            return None
        rec = env.get("rec")
        if not isinstance(rec, dict) or "sum" not in env:
            return None
        try:
            if int(env["sum"]) != _checksum(rec):
                return None
        except (TypeError, ValueError):
            return None
        if not isinstance(rec.get("kind"), str):
            return None
        return rec

    def stats(self) -> Dict[str, int]:
        return dict(self._stats)


def replay_table(records: List[dict]) -> Dict[int, Dict[str, Any]]:
    """Fold a record stream into the per-request table it encodes.

    Returns ``{rid: {"prompt": [...], "max_new_tokens": n,
    "deadline_s": ..., "tokens": [...], "state": "queued" | "decoding"
    | "done" | "failed" | "evicted", "error": ...}}``.  Token records
    for an unknown rid (their ``submit`` line was corrupted away) are
    dropped — a request the journal cannot prove was admitted is not
    resurrected from its decode trail alone.
    """
    table: Dict[int, Dict[str, Any]] = {}
    for rec in records:
        kind = rec.get("kind")
        rid = rec.get("rid")
        if kind == "submit" and isinstance(rid, int):
            table[rid] = {
                "prompt": list(rec.get("prompt", [])),
                "max_new_tokens": int(rec.get("max_new_tokens", 0)),
                "deadline_s": rec.get("deadline_s"),
                "tokens": [],
                "state": "queued",
                "error": None,
            }
        elif kind == "token" and rid in table:
            # position-addressed: ``step`` is the 1-based token index, so
            # a replayed run re-journaling steps it already wrote
            # overwrites in place instead of duplicating, and a token
            # whose predecessors were corrupted away (a hole in the
            # prefix) is dropped rather than stitched out of order.
            row = table[rid]
            pos = rec.get("step")
            if row["state"] in ("queued", "decoding") and isinstance(
                    pos, int) and pos >= 1:
                toks = row["tokens"]
                if pos <= len(toks):
                    toks[pos - 1] = int(rec["token"])
                elif pos == len(toks) + 1:
                    toks.append(int(rec["token"]))
                row["state"] = "decoding"
        elif kind == "preempt" and rid in table:
            # memory-pressure preemption (PR 10): the request went back
            # to the queue with its pages released.  Journaled tokens
            # are kept — recompute-on-resume is deterministic, so they
            # become position-addressed replay expectations that the
            # regenerated run must reproduce bit-exactly.
            if table[rid]["state"] in ("queued", "decoding"):
                table[rid]["state"] = "queued"
        elif kind in ("done", "failed", "evicted") and rid in table:
            table[rid]["state"] = kind
            table[rid]["error"] = rec.get("error")
    return table
