"""Paged KV cache: block-table indirection over a shared page pool.

The serving cache stops being one contiguous ``(B, max_len)`` strip per
request and becomes a pool of fixed-size *pages* — ``(n_layers, n_kv,
n_pages, page_size, d_head)`` for K and V — plus a per-request list of
page ids.  A request's logical KV positions ``[0, kv_len)`` live in
``pages[0], pages[1], ...`` in order; the last page may be partially
filled (positions past ``kv_len`` are stale and masked by the per-row
band, never read by compute).

The point of the layout (the PR-8 tentpole): a page table *is* an
index map.  ``ops.paged_attention`` feeds each request's page-id row
through ``PrefetchScalarGridSpec`` — the kernel's KV index map reads
``block_tables[row, j]`` to pick which pool page grid step ``j`` DMAs,
so the gather from scattered pages into the systolic array is free; no
host-side ``gather()`` materializes a contiguous view on the hot path.
(``gather()`` below exists for the XLA fallback and for seeding a
chunked prefill from reused prefix pages.)

Sharing falls out of indirection: pages are refcounted, and full pages
are registered in a prefix chain keyed ``(parent_key, token_chunk)``,
so two prompts with a common prefix share the prefix's pages —
``lookup_prefix`` returns the shared pages (incref'd) and how many
positions they cover, and the scheduler only prefills the tail.  The
chain key includes the parent, so a chunk match at position k implies
the *entire* prefix up to k matched — no false sharing between prompts
that agree on one middle chunk only.

Memory pressure (the PR-10 tentpole) adds a second tier below the
device pool: ``spill(pages)`` moves a cold request's private pages to
per-page host numpy buffers and returns the device copies to the free
list; ``unspill(entries)`` round-trips them back bit-exactly.  Shared
prefix pages (refcount > 1) are never copied — the spilling request
keeps its reference and the entry records the still-resident page id,
so a later ``unspill`` rebuilds the exact page list without touching
them.  High/low watermarks over pool occupancy give the scheduler a
hysteresis band: admission defers above ``high_watermark`` and spilled
requests resume below ``low_watermark``.

Bookkeeping (free list, refcounts, prefix chain) is host-side and O(1)
per page; only the page payload lives on device.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.runtime import health

# Pressure drills: pool.alloc fires inside alloc() — a `raise` kind is
# absorbed as a simulated OOM (alloc returns None), driving the
# spill -> preempt -> backpressure ladder without real exhaustion.
# pool.spill fires at the top of spill(); its `kill` kind is the
# SIGKILL-mid-spill crash drill (spill never touches the journal, so
# cold replay re-prefills and nothing is lost or duplicated).
health.register_site("pool.alloc")
health.register_site("pool.spill")


def pages_for(seq: int, page_size: int) -> int:
    """Pages needed to hold ``seq`` KV positions (ceil division)."""
    return max(0, -(-int(seq) // int(page_size)))


def _strict_pool() -> bool:
    return os.environ.get("REPRO_STRICT_POOL", "0") not in ("", "0")


class PagedKVCache:
    """Refcounted page pool with prefix reuse for one model config.

    ``cfg`` needs ``n_layers`` / ``n_kv_heads`` / ``d_head`` (any
    attention ModelConfig).  The pool is allocated eagerly: K and V
    pools of shape ``(n_layers, n_kv_heads, n_pages + 1, page_size,
    d_head)`` — the page axis is shared by every layer, so one page id
    resolves the same positions in all layers and the per-request block
    table stays a flat ``(max_pages,)`` int row.  The extra page at
    index ``n_pages`` is the *scratch* page: paged decode scatters
    inactive batch rows' writes there, so it is never allocated, never
    referenced by a block table, and never read.
    """

    def __init__(self, cfg, n_pages: int, page_size: int = 16,
                 dtype="bfloat16", high_watermark: float = 0.90,
                 low_watermark: float = 0.60):
        if n_pages < 1:
            raise ValueError(f"need at least one page, got {n_pages}")
        kv_dt = jnp.dtype(dtype if getattr(cfg, "kv_cache_dtype", "auto")
                          in ("auto", None) else cfg.kv_cache_dtype)
        shape = (cfg.n_layers, cfg.n_kv_heads, n_pages + 1, page_size,
                 cfg.d_head)
        self.k_pages = jnp.zeros(shape, kv_dt)
        self.v_pages = jnp.zeros(shape, kv_dt)
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.scratch = int(n_pages)          # write sink for idle rows
        self.high_watermark = float(high_watermark)
        self.low_watermark = float(low_watermark)
        self.refs = np.zeros(n_pages, np.int32)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        # prefix chain: (parent_key, token_chunk) -> page id, and the
        # inverse so a freed page drops its chain entry
        self._prefix: Dict[Tuple, int] = {}
        self._page_key: Dict[int, Tuple] = {}
        self.stats: Dict[str, int] = {
            "allocs": 0, "frees": 0, "reuse_hits": 0, "reuse_pages": 0,
            "oom_rejects": 0, "ref_underflows": 0,
            "spills": 0, "spilled_pages": 0, "unspills": 0,
        }

    # ------------------------------------------------------------------
    # Allocation.
    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, seq: int) -> bool:
        """Could a ``seq``-position request be paged right now (ignoring
        any prefix sharing it might get)?"""
        return pages_for(seq, self.page_size) <= len(self._free)

    def occupancy(self) -> float:
        """Fraction of the pool currently allocated (0.0 .. 1.0)."""
        return 1.0 - len(self._free) / self.n_pages

    def above_high(self) -> bool:
        return self.occupancy() >= self.high_watermark

    def below_low(self) -> bool:
        return self.occupancy() <= self.low_watermark

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` fresh pages (ref=1 each), or None if the pool
        cannot satisfy the request — the caller runs the pressure
        ladder (spill / preempt / defer), it does not partially
        allocate.  A ``pool.alloc`` raise-fault is absorbed as a
        simulated OOM so the ladder is drillable on a roomy pool."""
        try:
            health.maybe_inject("pool.alloc")
        except health.SimulatedFailure:
            self.stats["oom_rejects"] += 1
            return None
        if n > len(self._free):
            self.stats["oom_rejects"] += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pid in pages:
            self.refs[pid] = 1
        self.stats["allocs"] += n
        return pages

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; refcount 0 returns the page to
        the free list and retires its prefix-chain entry.  Releasing a
        page that is already free is a double-free: counted in
        ``ref_underflows`` (and fatal under ``REPRO_STRICT_POOL=1``)
        instead of silently clamping, because an underflow means some
        *other* request's shared prefix page just got freed under it."""
        for pid in pages:
            if self.refs[pid] <= 0:
                self.stats["ref_underflows"] += 1
                if _strict_pool():
                    raise RuntimeError(
                        f"page {pid} released with refcount "
                        f"{int(self.refs[pid])} (double free)")
                continue
            self.refs[pid] -= 1
            if self.refs[pid] == 0:
                key = self._page_key.pop(pid, None)
                if key is not None:
                    self._prefix.pop(key, None)
                self._free.append(pid)
                self.stats["frees"] += 1

    # ------------------------------------------------------------------
    # Host spill tier.
    # ------------------------------------------------------------------
    def spill(self, pages: Sequence[int]) -> List[Tuple]:
        """Move a request's pages to host memory, freeing device pages.

        Returns a list of spill entries, one per input page, in order:

        - ``("host", k_np, v_np)`` — the page was private (refcount 1);
          its payload was copied to host numpy buffers and the device
          page was released back to the free list.
        - ``("resident", pid)`` — the page is shared (refcount > 1), so
          copying it would waste host memory and releasing it would
          yank it from the other holders; the spilling request *keeps
          its reference* (the page stays pinned on device) and the
          entry just records the id.

        Spilling is invisible to the journal: a crash mid-spill (the
        ``pool.spill`` kill drill) recovers via cold replay, which
        re-prefills from the journaled prompt and never needs the
        spilled payload.
        """
        health.maybe_inject("pool.spill")
        entries: List[Tuple] = []
        n_host = 0
        for pid in pages:
            pid = int(pid)
            if self.refs[pid] > 1:
                entries.append(("resident", pid))
                continue
            k_np = np.asarray(self.k_pages[:, :, pid])
            v_np = np.asarray(self.v_pages[:, :, pid])
            entries.append(("host", k_np, v_np))
            self.release([pid])
            n_host += 1
        self.stats["spills"] += 1
        self.stats["spilled_pages"] += n_host
        return entries

    def unspill(self, entries: Sequence[Tuple]) -> Optional[List[int]]:
        """Round-trip spilled entries back onto device pages.

        Allocates one fresh page per ``("host", ...)`` entry, scatters
        the payloads back, and returns the request's full page list in
        original order (resident ids unchanged, host entries on their
        new pages).  Returns None — with ``entries`` untouched and no
        pages leaked — if the pool cannot currently hold the payload;
        the caller retries later or escalates the ladder.
        """
        need = sum(1 for e in entries if e[0] == "host")
        fresh = self.alloc(need) if need else []
        if fresh is None:
            return None
        pages: List[int] = []
        new_ids, chunks_k, chunks_v = [], [], []
        it = iter(fresh)
        for e in entries:
            if e[0] == "resident":
                pages.append(e[1])
                continue
            pid = next(it)
            pages.append(pid)
            new_ids.append(pid)
            chunks_k.append(e[1])
            chunks_v.append(e[2])
        if new_ids:
            idx = jnp.asarray(new_ids, jnp.int32)
            self.k_pages = self.k_pages.at[:, :, idx].set(
                jnp.asarray(np.stack(chunks_k, axis=2),
                            self.k_pages.dtype))
            self.v_pages = self.v_pages.at[:, :, idx].set(
                jnp.asarray(np.stack(chunks_v, axis=2),
                            self.v_pages.dtype))
        self.stats["unspills"] += 1
        return pages

    # ------------------------------------------------------------------
    # Prefix reuse.
    # ------------------------------------------------------------------
    def lookup_prefix(self, tokens) -> Tuple[List[int], int]:
        """Longest already-resident full-page prefix of ``tokens``.

        Returns ``(pages, covered)``: the shared pages *incref'd* (the
        caller owns one reference and must ``release`` them with the
        rest of the request's pages) and the number of positions they
        hold.  Never covers the whole prompt — the final token must be
        prefilled live so its logits exist — so ``covered`` stops at
        the last full page strictly before ``len(tokens)``.
        """
        toks = [int(t) for t in tokens]
        limit = (len(toks) - 1) // self.page_size * self.page_size
        pages: List[int] = []
        covered = 0
        parent: Tuple = ()
        while covered < limit:
            key = (parent, tuple(toks[covered:covered + self.page_size]))
            pid = self._prefix.get(key)
            if pid is None:
                break
            pages.append(pid)
            self.refs[pid] += 1
            parent = key
            covered += self.page_size
        if pages:
            self.stats["reuse_hits"] += 1
            self.stats["reuse_pages"] += len(pages)
        return pages, covered

    def store(self, tokens, pages: Sequence[int], covered: int,
              k_row, v_row) -> None:
        """Write a request's freshly-prefilled KV into its new pages.

        ``pages`` is the request's full page list (reused prefix first,
        as returned by ``lookup_prefix`` + ``alloc``); positions below
        ``covered`` are already resident and are not rewritten.
        ``k_row`` / ``v_row`` are the request's contiguous KV,
        ``(n_layers, n_kv_heads, >=plen, d_head)``.  Newly-stored *full*
        pages are registered in the prefix chain for later sharing; a
        partial tail page is private.
        """
        toks = [int(t) for t in tokens]
        plen = len(toks)
        ps = self.page_size
        first_new = covered // ps
        new_ids, chunks_k, chunks_v = [], [], []
        for gi in range(first_new, pages_for(plen, ps)):
            lo, hi = gi * ps, min((gi + 1) * ps, plen)
            chunk_k = k_row[:, :, lo:hi]
            chunk_v = v_row[:, :, lo:hi]
            if hi - lo < ps:              # partial tail: pad with zeros
                pad = [(0, 0), (0, 0), (0, ps - (hi - lo)), (0, 0)]
                chunk_k = jnp.pad(chunk_k, pad)
                chunk_v = jnp.pad(chunk_v, pad)
            new_ids.append(pages[gi])
            chunks_k.append(chunk_k)
            chunks_v.append(chunk_v)
        if new_ids:
            idx = jnp.asarray(new_ids, jnp.int32)
            self.k_pages = self.k_pages.at[:, :, idx].set(
                jnp.stack(chunks_k, axis=2).astype(self.k_pages.dtype))
            self.v_pages = self.v_pages.at[:, :, idx].set(
                jnp.stack(chunks_v, axis=2).astype(self.v_pages.dtype))
        # register full pages in the prefix chain, walking parents from
        # the start so reused pages re-derive the same keys
        parent: Tuple = ()
        for gi in range(plen // ps):
            key = (parent, tuple(toks[gi * ps:(gi + 1) * ps]))
            pid = pages[gi]
            if gi >= first_new and pid not in self._page_key \
                    and key not in self._prefix:
                self._prefix[key] = pid
                self._page_key[pid] = key
            parent = key

    # ------------------------------------------------------------------
    # Views.
    # ------------------------------------------------------------------
    def gather(self, pages: Sequence[int]):
        """Contiguous ``(n_layers, n_kv_heads, len(pages)*page, d_head)``
        K/V views of a request — the XLA-fallback / chunked-prefill
        seed path.  The kernel path never calls this; it reads the pool
        through the block-table index map."""
        idx = jnp.asarray(list(pages), jnp.int32)
        shp = self.k_pages.shape
        k = self.k_pages[:, :, idx].reshape(
            shp[0], shp[1], len(pages) * self.page_size, shp[4])
        v = self.v_pages[:, :, idx].reshape(
            shp[0], shp[1], len(pages) * self.page_size, shp[4])
        return k, v

    def block_table(self, pages: Sequence[int], max_pages: int):
        """One request's ``(max_pages,)`` int32 block-table row, padded
        with page 0 (padding is clamped out by the kernel's banded
        index map, never dereferenced for compute)."""
        row = np.zeros(max_pages, np.int32)
        row[:len(pages)] = np.asarray(list(pages), np.int32)
        return row

    def block_tables(self, page_lists: Sequence[Sequence[int]]):
        """Stacked ``(B, max_pages)`` table for a batch."""
        mp = max(1, max((len(p) for p in page_lists), default=1))
        return np.stack([self.block_table(p, mp) for p in page_lists])

    def report(self) -> Dict[str, int]:
        out = dict(self.stats)
        out["pages_total"] = self.n_pages
        out["pages_free"] = len(self._free)
        out["pages_shared"] = int(np.sum(self.refs > 1))
        out["occupancy"] = round(self.occupancy(), 4)
        out["above_high"] = self.above_high()
        out["below_low"] = self.below_low()
        return out
